"""Fused all-to-all dispatch/combine — one Pallas kernel for the whole hop.

``core.device.a2a_dispatch`` used to lower ``ff_a2a`` as four separate XLA
programs per batch: ``router_topk`` for the capacity positions, a scatter
into ``(nR, cap)`` expert lanes, a per-expert compute loop, and a gather to
combine back in stream order.  This kernel fuses the entire hop into a
single ``pallas_call``: route (softmax + top-1), capacity position, expert
compute, and combine all happen per token block while the activations are
hot, and the ``(nR, cap)`` lane buffer is never materialized in HBM.

The per-expert counters live in int32 VMEM scratch and carry across token
blocks (the grid's sequential dimension) — they ARE the bounded SPSC lanes
of FastFlow's all-to-all, reduced to their essence: each counter is a lane's
write cursor, monotonically claimed first-come-first-served as tokens
stream past, and a token finding its cursor at ``capacity`` is the
synchronous SPMD rendering of a blocked push (the host runtime would
back-pressure; a fixed-shape device program must drop and zero-fill).  The
lane *storage* disappears entirely: because every expert's output for a
token can be computed where the token already sits, "enqueue into the lane,
service it, collect" collapses into "compute and select", and only the
cursor — the one piece of state the queue semantics actually need — remains
in VMEM.

Combine is pure selection (top-1's normalized weight is identically 1.0),
so outputs are bit-identical to applying the routed expert directly under
the same jit — ``kernels/ref.a2a_fused_ref`` asserts exactly that (jitted:
eager mode rounds multiply-add chains without FMA contraction, a 1-ulp
eager-mode artifact, and production segments are always jitted) — and
``interpret`` is
resolved through :mod:`kernels.backend` so the CPU CI verifies the same
kernel body that lowers to Mosaic on a TPU host.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import default_interpret


def _kernel(logits_ref, xs_ref, out_ref, keep_ref, counts_ref, *,
            fns, E, capacity, bt, in_shape, out_shape):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # -- route: softmax + top-1 (the router_topk math, K=1) -----------------
    logits = logits_ref[...].astype(jnp.float32)          # (bt, E)
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)    # (bt,)

    # -- capacity position: running VMEM lane cursors + rank in this block --
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (bt, E)
    within = jnp.cumsum(onehot, axis=0) - onehot          # exclusive rank
    base = counts_ref[...]                                # (E,)
    pos = jnp.sum((within + base[None, :]) * onehot, axis=-1)
    keep = pos < capacity                                 # (bt,)

    # -- expert compute + combine, in-register ------------------------------
    xs = xs_ref[...].reshape((bt,) + in_shape)
    sel = jnp.zeros((bt,) + out_shape[1:], out_ref.dtype)
    for j, fn in enumerate(fns):
        yj = jax.vmap(fn)(xs).reshape((bt,) + out_shape[1:])
        sel = jnp.where((idx == j).reshape((bt,) + (1,) * (sel.ndim - 1)),
                        yj, sel)
    mask = keep.reshape((bt,) + (1,) * (sel.ndim - 1))
    out_ref[...] = jnp.where(mask, sel, jnp.zeros_like(sel))
    keep_ref[...] = keep.reshape(bt, 1)
    counts_ref[...] = base + jnp.sum(onehot, axis=0)


def _pick_block(T: int, block_t: Optional[int], E: int, Din: int) -> int:
    """Requested block, else the autotuned winner for this shape, else a
    heuristic — always snapped down to a divisor of T."""
    if block_t is None:
        try:  # lazy: kernels must stay importable without the core package
            from ..core import perf_model as pm
            rec = pm.lookup_autotuned(f"a2a_fused:T{T}:E{E}:D{Din}")
            if rec:
                block_t = int(rec["block_t"])
        except Exception:   # noqa: BLE001 - tuning is advisory, never fatal
            block_t = None
    if block_t is None:
        block_t = 128
    bt = max(1, min(block_t, T))
    while T % bt:
        bt -= 1
    return bt


def a2a_fused(logits, xs, expert_fns: Sequence[Callable], capacity: int, *,
              block_t: Optional[int] = None,
              interpret: Optional[bool] = None):
    """logits: (T, E); xs: (T, *item) already left-mapped items;
    ``expert_fns`` the E right workers (pure, array-in/array-out, agreeing
    on output shape/dtype).  Returns ``(out (T, *expert_out), keep (T,))``
    with over-capacity tokens zero-filled and ``keep=False``."""
    T, E = logits.shape
    if len(expert_fns) != E:
        raise ValueError(f"logits width {E} != {len(expert_fns)} experts")
    in_shape = xs.shape[1:]
    Din = int(math.prod(in_shape)) if in_shape else 1
    item = jax.ShapeDtypeStruct(in_shape, xs.dtype)
    outs = [jax.eval_shape(fn, item) for fn in expert_fns]
    if any(o.shape != outs[0].shape or o.dtype != outs[0].dtype
           for o in outs[1:]):
        raise ValueError("a2a experts must agree on output shape/dtype: "
                         f"{[(o.shape, str(o.dtype)) for o in outs]}")
    per_out = outs[0]
    Dout = int(math.prod(per_out.shape)) if per_out.shape else 1
    bt = _pick_block(T, block_t, E, Din)
    nt = T // bt

    kernel = functools.partial(
        _kernel, fns=tuple(expert_fns), E=E, capacity=capacity, bt=bt,
        in_shape=in_shape, out_shape=(bt, Dout))
    out, keep = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0)),
                  pl.BlockSpec((bt, Din), lambda t: (t, 0))],
        out_specs=[pl.BlockSpec((bt, Dout), lambda t: (t, 0)),
                   pl.BlockSpec((bt, 1), lambda t: (t, 0))],
        out_shape=[jax.ShapeDtypeStruct((T, Dout), per_out.dtype),
                   jax.ShapeDtypeStruct((T, 1), jnp.bool_)],
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32)],
        interpret=default_interpret(interpret),
    )(logits, xs.reshape(T, Din))
    return out.reshape((T,) + per_out.shape), keep[:, 0]
