"""One backend-detection helper for every Pallas call site.

Before this module each kernel carried its own notion of "am I on a TPU":
``ops.py`` had a private ``_on_tpu()``, while ``router_topk.py`` and
``flash_attention.py`` defaulted ``interpret=True`` unconditionally — correct
on the CPU CI container, silently interpreted (100x slow) on a real TPU host.
Every ``pallas_call`` now resolves its ``interpret`` flag through
:func:`default_interpret` so the kernels compile to Mosaic exactly when a TPU
backend is present and fall back to the Python interpreter everywhere else.
"""

from __future__ import annotations

from typing import Optional

import jax


def on_tpu() -> bool:
    """True when the default JAX backend is a real TPU."""
    return jax.default_backend() == "tpu"


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a ``pallas_call`` ``interpret`` flag: an explicit value wins,
    ``None`` means "interpret everywhere except on a TPU"."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)
