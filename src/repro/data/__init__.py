from .pipeline import DataPipeline, make_pipeline
from .sources import MemmapTokenSource, SyntheticLMSource

__all__ = ["DataPipeline", "make_pipeline", "MemmapTokenSource",
           "SyntheticLMSource"]
