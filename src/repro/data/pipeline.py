"""Host data pipeline = an FFGraph program carrying real traffic.

A building-blocks pipeline feeds the training loop:

    pipeline( Reader source, DevicePut stage[, compute stage] )

compiled through the staged graph compiler (``FFGraph.compile``): the reader
and device-put boundary stay host-placed (stateful nodes over SPSC queues),
and an optional pure ``compute`` stage — e.g. tokenization-as-a-matmul or
augmentation with declared ``ff_flops`` — is cost-placed onto the mesh, so a
single graph runs as a *hybrid* plan: reader threads feeding a sharded
compute farm through device-put boundary nodes.

The runner's bounded results queue provides back-pressure (the device never
waits on the host unless the host truly falls behind — and the host can
never run unboundedly ahead), exactly the role of FastFlow's fixed-capacity
lanes.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from ..core.graph import FFGraph, pipeline as ff_pipeline, seq as ff_seq
from ..core.node import FFNode


class _ReaderNode(FFNode):
    def __init__(self, source, n_batches: Optional[int]):
        super().__init__()
        self.source = source
        self.n = n_batches
        self.emitted = 0

    def svc(self, _):
        if self.n is not None and self.emitted >= self.n:
            return None
        self.emitted += 1
        return self.source.next_batch()


class _DevicePutNode(FFNode):
    """Moves a host batch onto the mesh with the right shardings (the
    emitter's scatter — SPMC over the data axis)."""

    def __init__(self, shardings: Optional[Any]):
        super().__init__()
        self.shardings = shardings

    def svc(self, batch):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return jax.device_put(batch, self.shardings)


class DataPipeline:
    """run_then_freeze()-style accelerator interface: the training loop just
    calls ``get()``; EOS -> None.  ``self.graph`` is the FFGraph program and
    ``self.placements`` the compiler's per-stage host/device decisions."""

    def __init__(self, source, shardings=None, n_batches: Optional[int] = None,
                 prefetch: int = 2, compute: Optional[Callable] = None,
                 plan=None):
        self.source = source
        stages = [_ReaderNode(source, n_batches), _DevicePutNode(shardings)]
        if compute is not None:
            # a pure seq stage, NOT a farm: the training loop consumes an
            # ordered stream and the checkpoint cursor assumes it — a host
            # farm's collector is arrival-ordered, so width must stay 1 here;
            # both the host FnNode and the device boundary node are FIFO
            stages.append(ff_seq(compute, pure=True))
        self.graph: FFGraph = ff_pipeline(*stages)
        self._runner = self.graph.compile(
            plan if compute is not None else None,
            capacity=max(2, prefetch), results_capacity=max(2, prefetch),
            device_batch=1)
        self.placements = getattr(self._runner, "placements", [])
        self._started = False

    def start(self) -> "DataPipeline":
        self._runner.start_stream()
        self._started = True
        return self

    def get(self, timeout: Optional[float] = None):
        return self._runner.get(timeout)

    def state(self) -> dict:
        # NOTE: prefetched-but-unconsumed batches are re-generated on
        # restore; the source cursor is saved *behind* the prefetch depth.
        return self.source.state()

    def stop(self) -> None:
        # drain: sources are finite or the process exits with daemon threads
        pass


def make_pipeline(source, plan=None, n_batches=None, prefetch: int = 2,
                  compute: Optional[Callable] = None) -> DataPipeline:
    shardings = None
    if plan is not None:
        st = source.state()          # peek one batch without consuming it
        probe = source.next_batch()
        source.restore(st)
        shardings = {
            k: plan.sharding_for(("batch",) + (None,) * (v.ndim - 1), v.shape)
            for k, v in probe.items()}
    return DataPipeline(source, shardings, n_batches, prefetch,
                        compute=compute, plan=plan).start()
