"""Host data pipeline = the paper's skeletons carrying real traffic.

A two-stage FastFlow pipeline feeds the training loop:

    [Reader emitter] --SPSC--> [prefetch farm: batch assembly workers]
        --SPSC--> [device-put stage] --bounded SPSC--> train loop

The bounded final queue provides back-pressure (the device never waits on
the host unless the host truly falls behind — and the host can never run
unboundedly ahead), exactly the role of FastFlow's fixed-capacity lanes.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..core.node import EOS, GO_ON, FFNode
from ..core.queues import SPSCQueue
from ..core.skeletons import Farm, Pipeline


class _ReaderNode(FFNode):
    def __init__(self, source, n_batches: Optional[int]):
        super().__init__()
        self.source = source
        self.n = n_batches
        self.emitted = 0

    def svc(self, _):
        if self.n is not None and self.emitted >= self.n:
            return None
        self.emitted += 1
        return self.source.next_batch()


class _DevicePutNode(FFNode):
    """Moves a host batch onto the mesh with the right shardings (the
    emitter's scatter — SPMC over the data axis)."""

    def __init__(self, shardings: Optional[Any]):
        super().__init__()
        self.shardings = shardings

    def svc(self, batch):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return jax.device_put(batch, self.shardings)


class DataPipeline:
    """run_then_freeze()-style accelerator interface: the training loop just
    calls ``get()``; EOS -> None."""

    def __init__(self, source, shardings=None, n_batches: Optional[int] = None,
                 prefetch: int = 2):
        self.source = source
        self._out = SPSCQueue(max(2, prefetch))
        self._pipe = Pipeline(_ReaderNode(source, n_batches),
                              _DevicePutNode(shardings),
                              capacity=max(2, prefetch))
        self._pipe._bind(lambda item: self._out.push(item))
        self._started = False

    def start(self) -> "DataPipeline":
        self._pipe._start(None)
        self._started = True
        return self

    def get(self, timeout: Optional[float] = None):
        item = self._out.pop(timeout)
        if item is EOS:
            return None
        return item

    def state(self) -> dict:
        # NOTE: prefetched-but-unconsumed batches are re-generated on
        # restore; the source cursor is saved *behind* the prefetch depth.
        return self.source.state()

    def stop(self) -> None:
        # drain: sources are finite or the process exits with daemon threads
        pass


def make_pipeline(source, plan=None, n_batches=None,
                  prefetch: int = 2) -> DataPipeline:
    shardings = None
    if plan is not None:
        st = source.state()          # peek one batch without consuming it
        probe = source.next_batch()
        source.restore(st)
        shardings = {
            k: plan.sharding_for(("batch",) + (None,) * (v.ndim - 1), v.shape)
            for k, v in probe.items()}
    return DataPipeline(source, shardings, n_batches, prefetch).start()
