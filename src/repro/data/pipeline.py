"""Host data pipeline = an FFGraph program carrying real traffic.

A building-blocks pipeline feeds the training loop:

    pipeline( Reader source, DevicePut stage[, compute stage/farm] )

compiled through the staged graph compiler (``FFGraph.compile``): the reader
and device-put boundary stay host-placed (stateful nodes over SPSC queues),
and an optional pure ``compute`` stage — e.g. tokenization-as-a-matmul or
augmentation with declared ``ff_flops`` — is cost-placed onto the mesh, so a
single graph runs as a *hybrid* plan: reader threads feeding a sharded
compute farm through device-put boundary nodes.

With ``compute_workers > 1`` the compute stage becomes a *process-placed
farm*: OS-process workers over shared-memory SPSC lanes
(``core.process.ProcessFarmNode``), so CPU-bound augmentation scales with
cores instead of serializing on the GIL.  The process farm's collector is
sequence-ordered, which is what licenses farming here at all — the training
loop consumes an ordered stream and the checkpoint cursor assumes it (a
*thread* farm's collector is arrival-ordered and must keep width 1).

The runner's bounded results queue provides back-pressure (the device never
waits on the host unless the host truly falls behind — and the host can
never run unboundedly ahead), exactly the role of FastFlow's fixed-capacity
lanes.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Union

import jax

from ..core.graph import (FFGraph, farm as ff_farm, pipeline as ff_pipeline,
                          seq as ff_seq)
from ..core.node import FFNode


class _ReaderNode(FFNode):
    def __init__(self, source, n_batches: Optional[int]):
        super().__init__()
        self.source = source
        self.n = n_batches
        self.emitted = 0

    def svc(self, _):
        if self.n is not None and self.emitted >= self.n:
            return None
        self.emitted += 1
        return self.source.next_batch()


class _DevicePutNode(FFNode):
    """Moves a host batch onto the mesh with the right shardings (the
    emitter's scatter — SPMC over the data axis)."""

    def __init__(self, shardings: Optional[Any]):
        super().__init__()
        self.shardings = shardings

    def svc(self, batch):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return jax.device_put(batch, self.shardings)


class DataPipeline:
    """run_then_freeze()-style accelerator interface: the training loop just
    calls ``get()``; EOS -> None.  ``self.graph`` is the FFGraph program and
    ``self.placements`` the compiler's per-stage host/device decisions."""

    def __init__(self, source, shardings=None, n_batches: Optional[int] = None,
                 prefetch: int = 2, compute: Optional[Callable] = None,
                 plan=None, compute_workers: Union[int, str] = 1,
                 shm_slot_bytes: int = 1 << 20, adaptive: bool = False,
                 transport: Optional[Any] = None):
        self.source = source
        placements = None
        if compute is not None and compute_workers not in (None, 1):
            # a farm is only admissible here when its collector keeps the
            # stream ordered (the training loop and checkpoint cursor assume
            # it): the process tier reorders by sequence number, so pin the
            # stage there — thread farms stay width 1.  The farm sits
            # *before* the device-put boundary: worker processes transform
            # raw numpy batches; only the parent touches the mesh.
            stages = [_ReaderNode(source, n_batches),
                      ff_farm(compute, n=compute_workers),
                      _DevicePutNode(shardings)]
            placements = {compute: "host_process"}
        else:
            stages = [_ReaderNode(source, n_batches),
                      _DevicePutNode(shardings)]
            if compute is not None:
                # single pure seq stage: both the host FnNode and the device
                # boundary node are FIFO
                stages.append(ff_seq(compute, pure=True))
        self.graph: FFGraph = ff_pipeline(*stages)
        from ..core.compiler import CompileConfig
        # the device boundary prefetches through the overlapped window: up
        # to ``prefetch`` compute batches ride in flight behind the one the
        # training loop is consuming (microbatch stays 1 — each source
        # batch is already the device-sized unit here), and the bounded
        # results queue still back-pressures the whole pipeline
        self._runner = self.graph.compile(config=CompileConfig(
            plan=plan if compute is not None else None,
            capacity=max(2, prefetch), results_capacity=max(2, prefetch),
            device_batch=1, placements=placements,
            shm_slot_bytes=shm_slot_bytes, adaptive=adaptive,
            transport=transport, overlap=True, inflight=max(2, prefetch)))
        self.placements = getattr(self._runner, "placements", [])
        # adaptive mode: a Supervisor thread samples the runner's stage
        # handles, re-places the compute farm live (width + thread/process
        # tier) from observed stats, and feeds perf_model.observe so the
        # next compile()'s placement improves.  The ordered-stream contract
        # holds: adaptive farm collectors are sequence-ordered on both tiers.
        self.supervisor = None
        if adaptive:
            from ..core.runtime import Supervisor
            self.supervisor = Supervisor(self._runner)
        self._started = False

    def start(self) -> "DataPipeline":
        self._runner.start_stream()
        if self.supervisor is not None:
            self.supervisor.start()
        self._started = True
        return self

    def get(self, timeout: Optional[float] = None):
        return self._runner.get(timeout)

    def state(self) -> dict:
        # NOTE: prefetched-but-unconsumed batches are re-generated on
        # restore; the source cursor is saved *behind* the prefetch depth.
        return self.source.state()

    def stats(self) -> dict:
        """Runner stats: per-node service-time EMA, items, lane depths."""
        s = self._runner.stats()
        if self.supervisor is not None:
            s["supervisor"] = self.supervisor.stats()
        return s

    def replacement_events(self):
        """Re-placement events (for the launcher's placement report)."""
        if self.supervisor is not None:
            return list(self.supervisor.events)
        return self._runner.replacement_events()

    def stop(self) -> None:
        # drain: sources are finite or the process exits with daemon threads
        if self.supervisor is not None:
            self.supervisor.stop()


def make_pipeline(source, plan=None, n_batches=None, prefetch: int = 2,
                  compute: Optional[Callable] = None,
                  compute_workers: Union[int, str] = 1,
                  adaptive: bool = False) -> DataPipeline:
    shardings = None
    if plan is not None:
        st = source.state()          # peek one batch without consuming it
        probe = source.next_batch()
        source.restore(st)
        if compute is not None and compute_workers not in (None, 1):
            # the process farm runs *before* the device-put boundary, so
            # the shardings must fit compute's output (it may change keys
            # or shapes), not the raw source batch
            probe = compute(probe)
        shardings = {
            k: plan.sharding_for(("batch",) + (None,) * (v.ndim - 1), v.shape)
            for k, v in probe.items()}
    return DataPipeline(source, shardings, n_batches, prefetch,
                        compute=compute, plan=plan,
                        compute_workers=compute_workers,
                        adaptive=adaptive).start()
