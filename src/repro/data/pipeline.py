"""Host data pipeline = an FFGraph program carrying real traffic.

A two-stage building-blocks pipeline feeds the training loop:

    pipeline( Reader source, DevicePut stage )  --lower()-->  host threads

    [Reader emitter] --SPSC--> [device-put stage] --bounded SPSC--> train loop

The graph is lowered through the single ``FFGraph.lower()`` entry point onto
host threads; the runner's bounded results queue provides back-pressure (the
device never waits on the host unless the host truly falls behind — and the
host can never run unboundedly ahead), exactly the role of FastFlow's
fixed-capacity lanes.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from ..core.graph import FFGraph, pipeline as ff_pipeline
from ..core.node import FFNode


class _ReaderNode(FFNode):
    def __init__(self, source, n_batches: Optional[int]):
        super().__init__()
        self.source = source
        self.n = n_batches
        self.emitted = 0

    def svc(self, _):
        if self.n is not None and self.emitted >= self.n:
            return None
        self.emitted += 1
        return self.source.next_batch()


class _DevicePutNode(FFNode):
    """Moves a host batch onto the mesh with the right shardings (the
    emitter's scatter — SPMC over the data axis)."""

    def __init__(self, shardings: Optional[Any]):
        super().__init__()
        self.shardings = shardings

    def svc(self, batch):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return jax.device_put(batch, self.shardings)


class DataPipeline:
    """run_then_freeze()-style accelerator interface: the training loop just
    calls ``get()``; EOS -> None.  ``self.graph`` is the FFGraph program."""

    def __init__(self, source, shardings=None, n_batches: Optional[int] = None,
                 prefetch: int = 2):
        self.source = source
        self.graph: FFGraph = ff_pipeline(_ReaderNode(source, n_batches),
                                          _DevicePutNode(shardings))
        self._runner = self.graph.lower(capacity=max(2, prefetch),
                                        results_capacity=max(2, prefetch))
        self._started = False

    def start(self) -> "DataPipeline":
        self._runner.start_stream()
        self._started = True
        return self

    def get(self, timeout: Optional[float] = None):
        return self._runner.get(timeout)

    def state(self) -> dict:
        # NOTE: prefetched-but-unconsumed batches are re-generated on
        # restore; the source cursor is saved *behind* the prefetch depth.
        return self.source.state()

    def stop(self) -> None:
        # drain: sources are finite or the process exits with daemon threads
        pass


def make_pipeline(source, plan=None, n_batches=None,
                  prefetch: int = 2) -> DataPipeline:
    shardings = None
    if plan is not None:
        st = source.state()          # peek one batch without consuming it
        probe = source.next_batch()
        source.restore(st)
        shardings = {
            k: plan.sharding_for(("batch",) + (None,) * (v.ndim - 1), v.shape)
            for k, v in probe.items()}
    return DataPipeline(source, shardings, n_batches, prefetch).start()
