"""Token sources with deterministic, checkpointable iteration state.

``state()``/``restore()`` return/consume a plain dict that the checkpoint
subsystem persists, so a restarted job resumes the stream exactly where it
left off (fault-tolerance requirement, DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMSource:
    """Deterministic synthetic LM data: Zipf-ish token draws from a counter-
    seeded PhiloxRNG — reproducible at any offset without replay."""

    def __init__(self, vocab: int, seq_len: int, batch_size: int,
                 seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        self._index = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed,
                                                   counter=self._index))
        self._index += 1
        # zipf-flavored distribution clipped to vocab
        toks = rng.zipf(1.3, size=(self.batch_size, self.seq_len))
        toks = (toks - 1) % self.vocab
        return {"tokens": toks.astype(np.int32)}

    def state(self) -> dict:
        return {"index": self._index, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self._index = int(state["index"])
        self.seed = int(state["seed"])


class MemmapTokenSource:
    """Flat binary token file (np.memmap) chopped into (batch, seq)
    windows — the standard pre-tokenized corpus layout."""

    def __init__(self, path, seq_len: int, batch_size: int,
                 dtype=np.int32, shard_id: int = 0, num_shards: int = 1):
        self.path = str(path)
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.dtype = np.dtype(dtype)
        self.shard_id = shard_id
        self.num_shards = num_shards
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        n_tokens = self._data.shape[0]
        self.n_windows = n_tokens // seq_len
        self._cursor = shard_id  # window index; strided by num_shards

    def next_batch(self) -> Dict[str, np.ndarray]:
        rows = []
        for _ in range(self.batch_size):
            w = self._cursor % self.n_windows
            rows.append(np.asarray(
                self._data[w * self.seq_len:(w + 1) * self.seq_len]))
            self._cursor += self.num_shards
        return {"tokens": np.stack(rows).astype(np.int32)}

    def state(self) -> dict:
        return {"cursor": self._cursor, "shard_id": self.shard_id,
                "num_shards": self.num_shards}

    def restore(self, state: dict) -> None:
        self._cursor = int(state["cursor"])
        self.shard_id = int(state["shard_id"])
        self.num_shards = int(state["num_shards"])


def write_token_file(path, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(str(path))
