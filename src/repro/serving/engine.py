"""Production serving tier — continuous batching, SLO-aware overload
policies, and per-request early exit, expressed as ONE FFGraph feedback
program.

The engine is a streaming network compiled through the staged compiler
(``compile(config=CompileConfig(...))``):

    pipeline( PrefillNode, CacheManager, DecodeNode, CollectNode
            ).wrap_around()

  PrefillNode   the farm stage AHEAD of admission: requests' KV caches are
                prefilled on a small worker pool concurrently with the
                decode tick (the jitted prefill drops the GIL), while the
                circulating control tokens bypass the farm on a fast path —
                a mid-stream prefill never stalls the batch;
  CacheManager  KV-cache management as a first-class graph stage: owns the
                slot free-list, the batched cache insert, the ready queue
                (per-tick slot REFILL — continuous batching), shed/evict
                accounting, and the cache-occupancy + SLO stats exposed
                through the :class:`~repro.core.graph.StageHandle` surface
                (``slo_controllable``: the adaptive Supervisor's
                :class:`~repro.core.runtime.SLOPolicy` pushes pressure
                levels down through it);
  DecodeNode    the batched SPMD decode worker — every active slot advances
                one token per tick, plus the per-slot confidence (max
                softmax probability) the early-exit policy consumes;
  CollectNode   the per-request collector: appends tokens, applies the
                FastBERT-style per-turn exit policy (confidence above the
                request's threshold), enforces deadlines (a request past
                its ``deadline_s`` finishes truncated), and delivers
                finished requests out of the loop (``Deliver``);
  feedback      the tick re-entering the loop head (``wrap_around``).

Client API (the supported surface)
----------------------------------
``engine.submit(Request) -> RequestHandle`` admits a request without
blocking: under overload it is *shed* — the handle resolves immediately to
a typed :class:`Overloaded` — or *degraded* (``max_new_tokens`` capped,
early exit tightened) instead of queueing unboundedly.
``handle.result(timeout)`` blocks for that request;
``engine.results()`` iterates every outcome in finish order;
``engine.close()`` drains and shuts down, and the engine is a context
manager (``with InferenceEngine(...) as eng:`` starts it, exit closes it).

The paper's accelerator mode (Sec. 9) remains verbatim as the compat
adapter: ``run_then_freeze()`` / ``offload(request)`` (blocking
back-pressure at ``max_pending``) / ``load_result()`` /
``offload(FF_EOS)`` + ``wait()``.

Overload policy
---------------
:class:`~repro.core.runtime.SLOPolicy` maps the waiting-backlog /
``max_pending`` ratio to a pressure level: 0 unconstrained, 1 degrade, 2
shed.  The engine enforces the policy inline on every ``submit`` (so it
works without a supervisor), and ``adaptive=True`` additionally attaches a
:class:`~repro.core.runtime.Supervisor` that samples the CacheManager's
``slo`` stats block and pushes pressure levels through the stage handle —
the effective level is the max of the two.  ``offload`` keeps the paper's
blocking semantics; host memory is bounded by ``max_pending`` either way.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Deque, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compiler import CompileConfig
from ..core.graph import Deliver, StageHandle, pipeline
from ..core.node import EOS, GO_ON, FFNode, _Sentinel
from ..core.runtime import SLOPolicy
from ..models.lm import LM
from ..runtime.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    id: int = -1
    # filled by the engine:
    tokens: Optional[List[int]] = None
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0
    # SLO / early-exit surface:
    deadline_s: Optional[float] = None  # wall budget from submit; truncates
    exit_threshold: Optional[float] = None  # confidence for early exit
    degraded: bool = False              # overload policy capped this request
    finish_reason: str = ""             # max_tokens | eos | early_exit |
    #                                     deadline


@dataclasses.dataclass
class Overloaded:
    """Typed shed result: the engine refused (or abandoned) ``request``
    under overload instead of queueing it unboundedly."""

    request: Request
    reason: str
    backlog: int = 0


class RequestHandle:
    """Future for one submitted request: resolves to the finished
    :class:`Request` or a typed :class:`Overloaded`."""

    def __init__(self, request: Request):
        self.request = request
        self._event = threading.Event()
        self._outcome: Union[Request, Overloaded, None] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None
               ) -> Union[Request, Overloaded]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.id} not finished in {timeout}s")
        return self._outcome

    def _resolve(self, outcome: Union[Request, Overloaded]) -> None:
        self._outcome = outcome
        self._event.set()


class _Accounting:
    """Shared request ledger: the one place submit/shed/admit/finish counts
    live, so admission back-pressure, the EOS decision, and the SLO stats
    all agree under concurrency."""

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.admitted = 0       # inserted into a batch slot
        self.finished = 0
        self.shed = 0

    def bump(self, field: str) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + 1)

    def waiting(self) -> int:
        """Requests accepted but not yet decoding (input queue + prefill +
        ready queue) — what admission back-pressure bounds."""
        with self._lock:
            return self.submitted - self.shed - self.admitted

    def in_flight(self) -> int:
        """Requests with an outcome still owed (anywhere in the engine)."""
        with self._lock:
            return self.submitted - self.shed - self.finished


class _SLOState:
    """Pressure shared between the inline policy, the supervisor handle,
    and the collector: ``level`` 0/1/2 per :class:`SLOPolicy`."""

    def __init__(self, policy: SLOPolicy):
        self.policy = policy
        self.ext_level = 0      # pushed down by the Supervisor, if attached


_TICK = _Sentinel("TICK")     # the circulating batch step
_DRAIN = _Sentinel("DRAIN")   # FF_EOS translated so admission can drain first
_END = _Sentinel("END")       # client-side end-of-results marker


@dataclasses.dataclass
class _Ready:
    """A prefilled request, queued for slot refill at the CacheManager."""

    req: Request
    tok: Any = None             # (1, 1) int32 first generated token
    cache1: Any = None          # B=1 KV cache pytree
    prompt_len: int = 0
    error: Optional[BaseException] = None


class _BatchState:
    """The batched decode state: KV caches for B slots + bookkeeping.
    Owned by whichever node currently holds the tick."""

    def __init__(self, cfg, B: int, cache_len: int):
        from ..configs.base import cache_specs
        self.caches = jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype),
                                   cache_specs(cfg, B, cache_len, None))
        self.cur_tok = jnp.zeros((B, 1), jnp.int32)
        self.pos = jnp.zeros((B,), jnp.int32)
        self.active_mask = np.zeros((B,), bool)
        self.last_toks: Optional[np.ndarray] = None
        self.last_conf: Optional[np.ndarray] = None
        # the in-flight decode step: (next_tokens, confidence) device arrays
        # dispatched by DecodeNode but not yet copied out — CollectNode
        # resolves them at the top of its turn, so the d2h copy (and the
        # compute remainder behind it) overlaps the hop between the nodes
        # and the next tick's slot-refill dispatch never waits on a host
        # sync inside the decode node
        self.pending: Optional[tuple] = None


class PrefillNode(FFNode):
    """The prefill farm AHEAD of admission (continuous batching's first
    half): requests fan out to a small worker pool that builds their KV
    caches concurrently with the decode tick, while control tokens
    (``_TICK``/``_DRAIN``) bypass the pool entirely — a long prompt being
    prefilled never stalls the running batch.

    All emissions (bypass AND worker completions) go through one lock, so
    the downstream SPSC lane still sees serialized pushes — the same
    discipline ``HostRunner`` uses on its multi-producer input queue."""

    def __init__(self, prefill, params, n_workers: int = 2):
        super().__init__()
        self._label = "prefill-farm"
        self._prefill = prefill
        self._params = params
        self.n_workers = max(1, n_workers)
        self._jobs: "queue.Queue[Any]" = queue.Queue()
        self._emit_lock = threading.Lock()
        self._workers: List[threading.Thread] = []
        self.prefills = 0

    def _emit(self, item: Any) -> None:
        with self._emit_lock:
            self.ff_send_out(item)

    def _worker(self) -> None:
        while True:
            req = self._jobs.get()
            if req is EOS:
                return
            try:
                prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
                tok, cache1 = self._prefill(self._params, prompt)
                tok.block_until_ready()
                out = _Ready(req, tok, cache1, int(prompt.shape[1]))
                with self._stats_lock:
                    self.prefills += 1
            except BaseException as e:  # noqa: BLE001 - surfaced as a shed
                out = _Ready(req, error=e)
            self._emit(out)

    def svc_init(self) -> int:
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"ff-prefill-{i}")
            for i in range(self.n_workers)]
        for t in self._workers:
            t.start()
        return 0

    def svc(self, item):
        if item is _TICK or item is _DRAIN or isinstance(item, _Sentinel):
            self._emit(item)            # fast path: never behind a prefill
        else:
            self._jobs.put(item)        # a Request: fan out to the pool
        return GO_ON

    def svc_end(self) -> None:
        for _ in self._workers:
            self._jobs.put(EOS)
        for t in self._workers:
            t.join(timeout=5.0)

    def node_stats(self) -> dict:
        s = super().node_stats()
        with self._stats_lock:
            s.update({"node": self._label, "prefills": self.prefills,
                      "queued": self._jobs.qsize(),
                      "workers": self.n_workers})
        return s


class _CacheManagerHandle(StageHandle):
    """The CacheManager's stage handle: read-only like the base handle,
    plus the SLO control surface the Supervisor's overload policy drives."""

    slo_controllable = True

    def __init__(self, cm: "CacheManager"):
        super().__init__("cache-manager", cm)
        self._cm = cm

    def stats(self) -> dict:
        return self._cm.node_stats()

    def set_pressure(self, level: int, policy: Optional[SLOPolicy] = None
                     ) -> None:
        if policy is not None:
            self._cm.slo.policy = policy
        self._cm.slo.ext_level = int(level)


class CacheManager(FFNode):
    """KV-cache management as a first-class graph stage: owns the slot
    free-list, the batched cache insert (eviction is the release back to
    the free list), the ready queue feeding per-tick slot REFILL, and the
    occupancy/SLO stats behind :meth:`make_handle`.  Terminates the whole
    loop (returns EOS) once draining and every accepted request has an
    outcome."""

    def __init__(self, state: _BatchState, B: int, insert,
                 acct: _Accounting, slo: _SLOState, max_pending: int):
        super().__init__()
        self._label = "cache-manager"
        self.state = state
        self.B = B
        self._insert = insert
        self.acct = acct
        self.slo = slo
        self.max_pending = max_pending
        self.free: List[int] = list(range(B))
        self.active: Dict[int, Request] = {}
        self.ready: Deque[_Ready] = collections.deque()
        self.inserts = 0
        self.evicts = 0
        self.draining = False
        self.holding = True          # the tick starts here
        self.drained = threading.Event()

    # -- slot lifecycle ----------------------------------------------------
    def release(self, slot: int) -> None:
        """Evict a finished request's cache slot (called by the collector,
        which holds the tick — never concurrent with a refill)."""
        self.active.pop(slot, None)
        self.free.append(slot)
        self.evicts += 1

    def _shed(self, req: Request, reason: str) -> None:
        self.acct.bump("shed")
        self.ff_send_out(Deliver(Overloaded(req, reason,
                                            self.acct.waiting())))

    def _refill(self) -> None:
        st = self.state
        now = time.perf_counter()
        while self.ready and self.free:
            r = self.ready.popleft()
            req = r.req
            if r.error is not None:
                self._shed(req, f"prefill failed: {r.error!r}")
                continue
            if (req.deadline_s is not None
                    and now - req.submit_t > req.deadline_s):
                self._shed(req, f"deadline {req.deadline_s}s expired "
                                "before admission")
                continue
            slot = self.free.pop()
            self.active[slot] = req
            st.caches, st.cur_tok, st.pos = self._insert(
                st.caches, r.cache1, st.cur_tok, st.pos, jnp.asarray(slot),
                r.tok, jnp.asarray(r.prompt_len, jnp.int32))
            req.tokens.append(int(r.tok[0, 0]))
            st.active_mask[slot] = True
            self.inserts += 1
            self.acct.bump("admitted")

    def _maybe_go(self):
        if not self.holding:
            return GO_ON                  # tick is downstream; queue up
        self._refill()
        if self.state.active_mask.any():
            self.holding = False
            return _TICK
        if self.draining and not self.ready and self.acct.in_flight() == 0:
            self.drained.set()
            return EOS                    # unwinds decode + collect too
        return GO_ON                      # idle: hold the tick, wait

    def svc(self, item):
        if item is _DRAIN:
            self.draining = True
        elif item is _TICK:
            self.holding = True           # back from the feedback edge
        elif isinstance(item, _Ready):
            self.ready.append(item)
        return self._maybe_go()

    # -- observability -----------------------------------------------------
    def node_stats(self) -> dict:
        s = super().node_stats()
        with self._stats_lock:
            occupied = len(self.active)
            s.update({
                "node": self._label,
                "cache": {"slots": self.B, "occupied": occupied,
                          "inserts": self.inserts, "evicts": self.evicts,
                          "ready": len(self.ready)},
                "slo": {"backlog": self.acct.waiting(),
                        "capacity": self.max_pending,
                        "in_flight": self.acct.in_flight(),
                        "shed": self.acct.shed,
                        "pressure": self.slo.ext_level},
            })
        return s

    def make_handle(self) -> StageHandle:
        return _CacheManagerHandle(self)


class DecodeNode(FFNode):
    """The batched decode worker: one SPMD step advances every active slot
    and reports each slot's next-token confidence (max softmax probability)
    for the early-exit policy.  Non-tick items (``Deliver`` escapes from
    upstream) pass straight through."""

    def __init__(self, state: _BatchState, params, decode):
        super().__init__()
        self._label = "decode"
        self.state = state
        self.params = params
        self._decode = decode
        self.steps = 0

    def svc(self, item):
        if item is not _TICK:
            return item                   # pass-through (Deliver, drain...)
        st = self.state
        nt, conf, st.caches = self._decode(
            self.params, st.caches, {"token": st.cur_tok, "pos": st.pos})
        st.cur_tok = nt
        st.pos = st.pos + jnp.asarray(st.active_mask, jnp.int32)
        self.steps += 1
        # the overlapped boundary, serving edition: do NOT sync here — start
        # the device->host copies and hand the unfinalized arrays down the
        # loop.  CollectNode resolves them, so the copy-out (and compute
        # remainder) rides under the decode->collect hop, and the next
        # tick's CacheManager refill dispatches behind the in-flight step
        # without a host sync in between
        for leaf in (nt, conf):
            copy = getattr(leaf, "copy_to_host_async", None)
            if copy is not None:
                try:
                    copy()
                except Exception:   # noqa: BLE001 - optional fast path
                    pass
        st.pending = (nt, conf)
        return _TICK


class CollectNode(FFNode):
    """Per-request collector: appends each active slot's token, applies the
    per-turn exit policy — target length, EOS token, FastBERT-style
    confidence exit, deadline truncation — releases finished slots back to
    the CacheManager, and delivers the requests out of the loop."""

    def __init__(self, state: _BatchState, cm: CacheManager,
                 acct: _Accounting, slo: _SLOState,
                 eos_token: Optional[int],
                 exit_threshold: Optional[float]):
        super().__init__()
        self._label = "collect"
        self.state = state
        self.cm = cm
        self.acct = acct
        self.slo = slo
        self.eos_token = eos_token
        self.exit_threshold = exit_threshold
        self.early_exits = 0

    def _exit_threshold_for(self, req: Request) -> Optional[float]:
        thr = (req.exit_threshold if req.exit_threshold is not None
               else self.exit_threshold)
        if thr is None:
            return None
        # under pressure (or for a degraded request) exit more aggressively:
        # accept a lower confidence to free the slot sooner
        if self.slo.ext_level >= 1 or req.degraded:
            thr = thr * self.slo.policy.exit_margin
        return thr

    def svc(self, item):
        if item is not _TICK:
            return item                   # pass-through
        st = self.state
        if st.pending is not None:        # resolve the in-flight decode step
            nt, conf = st.pending
            st.pending = None
            st.last_toks = np.asarray(nt[:, 0])
            st.last_conf = np.asarray(conf)
        now = time.perf_counter()
        for slot in list(self.cm.active):
            req = self.cm.active[slot]
            if not st.active_mask[slot]:
                continue
            t = int(st.last_toks[slot])
            req.tokens.append(t)
            conf = float(st.last_conf[slot]) if st.last_conf is not None \
                else 0.0
            thr = self._exit_threshold_for(req)
            reason = ""
            if len(req.tokens) >= req.max_new_tokens:
                reason = "max_tokens"
            elif self.eos_token is not None and t == self.eos_token:
                reason = "eos"
            elif thr is not None and conf >= thr:
                reason = "early_exit"
                self.early_exits += 1
            elif (req.deadline_s is not None
                  and now - req.submit_t > req.deadline_s):
                reason = "deadline"       # out of budget: truncate
            if reason:
                req.done = True
                req.finish_reason = reason
                req.finish_t = now
                st.active_mask[slot] = False
                self.cm.release(slot)
                self.acct.bump("finished")
                self.ff_send_out(Deliver(req))
        return _TICK                      # wrap_around -> loop head


class InferenceEngine:
    """Continuous-batching serving engine: an FFGraph feedback program with
    a typed client API (``submit``/``results``/``close``) in front and the
    paper's accelerator surface kept as the compat adapter."""

    def __init__(self, cfg, plan, params, *, max_batch: int = 4,
                 cache_len: int = 256, eos_token: Optional[int] = None,
                 adaptive: bool = False, max_pending: int = 256,
                 prefill_workers: int = 2,
                 exit_threshold: Optional[float] = None,
                 slo: Optional[SLOPolicy] = None):
        self.cfg = cfg
        self.plan = plan
        self.params = params
        self.B = max_batch
        self.cache_len = cache_len
        self.eos_token = eos_token
        self.model = LM(cfg)
        # admission back-pressure: offload() blocks / submit() sheds once
        # this many requests wait for a slot — host memory stays bounded
        # under any offered load
        self.max_pending = max_pending

        prefill_step = make_prefill_step(cfg, plan, cache_len)

        def _prefill(p, tokens):
            logits, cache1 = prefill_step(p, {"tokens": tokens})
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            return tok, cache1

        decode_step = make_decode_step(cfg, plan, cache_len)

        def _decode(p, caches, batch):
            nt, logits, caches = decode_step(p, caches, batch)
            conf = jnp.max(jax.nn.softmax(logits[:, -1, :], axis=-1), -1)
            return nt, conf, caches

        self._acct = _Accounting()
        self._slo = _SLOState(slo or SLOPolicy())
        self.state = _BatchState(cfg, self.B, cache_len)
        self._prefill_node = PrefillNode(jax.jit(_prefill), params,
                                         n_workers=prefill_workers)
        self._cm = CacheManager(self.state, self.B,
                                jax.jit(self._insert_impl), self._acct,
                                self._slo, max_pending)
        self._decode_node = DecodeNode(self.state, params, jax.jit(_decode))
        self._collect = CollectNode(self.state, self._cm, self._acct,
                                    self._slo, eos_token, exit_threshold)

        self.graph = pipeline(self._prefill_node, self._cm,
                              self._decode_node,
                              self._collect).wrap_around()
        # the nodes are stateful (slot free-list, batched caches), so
        # place() pins the feedback loop to host threads — the SPMD
        # prefill/decode steps inside the nodes are the device side
        self._runner = self.graph.compile(config=CompileConfig(
            capacity=self.max_pending, results_capacity=1024,
            adaptive=adaptive))
        self.placements = getattr(self._runner, "placements", [])
        self.supervisor = None
        if adaptive:
            from ..core.runtime import Supervisor
            self.supervisor = Supervisor(self._runner,
                                         slo=self._slo.policy)

        self._ids = itertools.count(0)
        self._handles: Dict[int, RequestHandle] = {}
        self._handles_lock = threading.Lock()
        self._results_q: "queue.Queue[Any]" = queue.Queue()
        self._dispatcher: Optional[threading.Thread] = None
        self._dispatcher_stop = threading.Event()
        self._started = False
        self._closing = False

    # -- introspection -----------------------------------------------------
    @property
    def steps(self) -> int:
        return self._decode_node.steps

    @property
    def early_exits(self) -> int:
        return self._collect.early_exits

    @property
    def shed_count(self) -> int:
        return self._acct.shed

    @property
    def error(self) -> Optional[BaseException]:
        return self._runner.error()

    def stats(self) -> dict:
        """Runner stats (per-node service EMA, cache occupancy, SLO block)
        plus the request ledger."""
        s = self._runner.stats()
        s["requests"] = {"submitted": self._acct.submitted,
                         "admitted": self._acct.admitted,
                         "finished": self._acct.finished,
                         "shed": self._acct.shed}
        if self.supervisor is not None:
            s["supervisor"] = self.supervisor.stats()
        return s

    def replacement_events(self):
        """Supervisor events (pressure changes, migrations) for reports."""
        if self.supervisor is not None:
            return list(self.supervisor.events)
        return self._runner.replacement_events()

    # -- caches ------------------------------------------------------------
    def _insert_impl(self, caches, new_cache, cur_tok, pos, slot, tok, p):
        """Write a single prefilled (B=1) cache into slot ``slot``."""
        def put(c, n):
            # c: (L, B, ...) or nested; n: (L, 1, ...)
            return jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
        caches = jax.tree.map(put, caches, new_cache)
        cur_tok = jax.lax.dynamic_update_slice(cur_tok, tok, (slot, 0))
        pos = pos.at[slot].set(p)
        return caches, cur_tok, pos

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "InferenceEngine":
        """Start the streaming network, the result dispatcher, and (in
        adaptive mode) the supervisor.  Idempotent."""
        if self._started:
            return self
        self._started = True
        self._runner.run_then_freeze()
        self._dispatcher = threading.Thread(target=self._dispatch,
                                            daemon=True,
                                            name="ff-serve-dispatch")
        self._dispatcher.start()
        if self.supervisor is not None:
            self.supervisor.start()
        return self

    def __enter__(self) -> "InferenceEngine":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _dispatch(self) -> None:
        """Single consumer of the runner's result stream: resolves request
        handles and feeds the client-facing results queue (which the compat
        ``load_result`` also reads)."""
        while True:
            try:
                ok, item = self._runner.load_result(0.2)
            except TimeoutError:
                if self._dispatcher_stop.is_set():
                    self._results_q.put(_END)
                    return
                continue
            if not ok:                    # network EOS: loop fully drained
                self._results_q.put(_END)
                return
            rid = (item.request.id if isinstance(item, Overloaded)
                   else item.id)
            with self._handles_lock:
                h = self._handles.pop(rid, None)
            if h is not None:
                h._resolve(item)
            self._results_q.put(item)

    # -- typed client API --------------------------------------------------
    def submit(self, req: Request) -> RequestHandle:
        """Admit a request without blocking.  Under overload the request is
        shed (handle resolves to :class:`Overloaded` immediately) or
        degraded (``max_new_tokens`` capped, earlier exit) per the engine's
        :class:`~repro.core.runtime.SLOPolicy`; the hard ``max_pending``
        cap always sheds."""
        if not self._started:
            self.start()
        if self._closing:
            raise RuntimeError("submit() on a closing engine")
        if req.id < 0:
            req.id = next(self._ids)
        req.tokens = []
        req.submit_t = time.perf_counter()
        handle = RequestHandle(req)
        self._acct.bump("submitted")
        waiting = self._acct.waiting()
        policy = self._slo.policy
        level = max(self._slo.ext_level,
                    policy.level(waiting, self.max_pending))
        if level >= 2 or waiting > self.max_pending:
            self._acct.bump("shed")
            ov = Overloaded(req, f"overloaded: backlog {waiting}/"
                                 f"{self.max_pending}", waiting)
            handle._resolve(ov)
            self._results_q.put(ov)
            return handle
        if level == 1:
            req.max_new_tokens = min(req.max_new_tokens,
                                     policy.degrade_tokens)
            req.degraded = True
        with self._handles_lock:
            self._handles[req.id] = handle
        self._runner.offload(req)
        return handle

    def results(self) -> Iterator[Union[Request, Overloaded]]:
        """Iterate every outcome (finished ``Request`` or ``Overloaded``)
        in completion order, until the engine is drained."""
        while True:
            item = self._results_q.get()
            if item is _END:
                self._results_q.put(_END)   # repeated iteration stays ended
                return
            yield item

    def close(self, timeout: Optional[float] = 60.0) -> int:
        """Stop accepting, drain in-flight requests, shut the network,
        supervisor, and dispatcher down.  Idempotent."""
        if not self._started:
            return 0
        if not self._closing:
            self._closing = True
            self._runner.offload(_DRAIN)
        return self.wait(timeout)

    # -- paper accelerator API (compat adapter) ----------------------------
    def run_then_freeze(self) -> int:
        self.start()
        return 0

    def offload(self, req) -> None:
        """Submit a request with the paper's blocking semantics (single
        producer): blocks while ``max_pending`` requests are waiting for a
        slot instead of shedding.  ``offload(FF_EOS)`` starts the drain."""
        if not self._started:
            self.start()
        if req is EOS:
            self._closing = True
            self._runner.offload(_DRAIN)
            return
        delay = 1e-5
        while (self.error is None
               and self._acct.waiting() >= self.max_pending):
            time.sleep(delay)
            delay = min(delay * 2, 1e-2)  # park, don't spin, while throttled
        if req.id < 0:
            req.id = next(self._ids)
        req.tokens = []
        req.submit_t = time.perf_counter()
        self._acct.bump("submitted")
        self._runner.offload(req)

    def load_result(self, timeout: Optional[float] = None):
        try:
            item = self._results_q.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError("load_result timed out") from None
        if item is _END:
            self._results_q.put(_END)
            return False, None
        return True, item

    def load_result_nb(self):
        try:
            item = self._results_q.get_nowait()
        except queue.Empty:
            return False, None
        if item is _END:
            self._results_q.put(_END)
            return False, None
        return True, item

    def wait(self, timeout: Optional[float] = None) -> int:
        """Join the drained network.  The terminating EOS originates
        mid-pipeline (the CacheManager), so once the loop reports drained
        this also unwinds the prefill stage ahead of it."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.error is not None or self._cm.drained.wait(0.05):
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
        terminating = self._cm.drained.is_set() or self.error is not None
        if terminating:
            self._runner.offload(EOS)     # unwind the prefill stage
        remaining = None if deadline is None \
            else max(0.0, deadline - time.monotonic())
        rc = self._runner.wait(remaining)
        if terminating:
            if self.supervisor is not None:
                self.supervisor.stop()    # idempotent — no _thread peeking
            self._dispatcher_stop.set()
            if self._dispatcher is not None:
                self._dispatcher.join(timeout=2.0)
        return rc
