"""Serving engine — FastFlow accelerator mode (paper Sec. 9) around a
continuous-batching decode loop.

Skeleton structure:
  emitter    = the SLOT SCHEDULER: a custom load balancer whose
               ``selectworker`` picks a free decode slot for each incoming
               request (paper Sec. 8.3 — user-defined scheduling policy);
  workers    = the batched SPMD decode step (all slots advance together —
               the device farm);
  collector  = per-request output queues (load_result / load_result_nb);
  feedback   = generated tokens re-entering the decode step (wrap_around).

The host API is the paper's accelerator API verbatim: ``run_then_freeze()``
starts the engine, ``offload(request)`` submits, ``load_result()`` blocks
for the next finished request, ``offload(FF_EOS)`` + ``wait()`` shut down.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.node import EOS
from ..core.queues import SPSCQueue
from ..models.lm import LM
from ..runtime.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    id: int = -1
    # filled by the engine:
    tokens: Optional[List[int]] = None
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0


class SlotScheduler:
    """The emitter's load-balancer: free-slot tracking (selectworker)."""

    def __init__(self, n_slots: int):
        self.free = list(range(n_slots))
        self.active: Dict[int, Request] = {}

    def selectworker(self) -> Optional[int]:
        return self.free.pop() if self.free else None

    def release(self, slot: int) -> None:
        self.active.pop(slot, None)
        self.free.append(slot)


class InferenceEngine:
    def __init__(self, cfg, plan, params, *, max_batch: int = 4,
                 cache_len: int = 256, eos_token: Optional[int] = None):
        self.cfg = cfg
        self.plan = plan
        self.params = params
        self.B = max_batch
        self.cache_len = cache_len
        self.eos_token = eos_token
        self.model = LM(cfg)

        self._prefill = jax.jit(make_prefill_step(cfg, plan, cache_len))
        self._decode = jax.jit(make_decode_step(cfg, plan, cache_len))
        self._insert = jax.jit(self._insert_impl)

        # batched state: caches for B slots + per-slot bookkeeping
        self.caches = jax.tree.map(
            lambda t: jnp.zeros(t.shape, t.dtype),
            self._cache_template())
        self.cur_tok = jnp.zeros((self.B, 1), jnp.int32)
        self.pos = jnp.zeros((self.B,), jnp.int32)
        self.active_mask = np.zeros((self.B,), bool)

        self.sched = SlotScheduler(self.B)
        self._in: SPSCQueue = SPSCQueue(256)
        self._out: SPSCQueue = SPSCQueue(1024)
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        self.steps = 0

    # -- caches -----------------------------------------------------------------
    def _cache_template(self):
        from ..configs.base import cache_specs
        return cache_specs(self.cfg, self.B, self.cache_len, None)

    def _insert_impl(self, caches, new_cache, cur_tok, pos, slot, tok, p):
        """Write a single prefilled (B=1) cache into slot ``slot``."""
        def put(c, n):
            # c: (L, B, ...) or nested; n: (L, 1, ...)
            return jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
        caches = jax.tree.map(put, caches, new_cache)
        cur_tok = jax.lax.dynamic_update_slice(cur_tok, tok, (slot, 0))
        pos = pos.at[slot].set(p)
        return caches, cur_tok, pos

    # -- paper accelerator API -----------------------------------------------------
    def run_then_freeze(self) -> int:
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="inference-engine")
        self._thread.start()
        return 0

    def offload(self, req) -> None:
        self._in.push(req)

    def load_result(self, timeout: Optional[float] = None):
        item = self._out.pop(timeout)
        if item is EOS:
            return False, None
        return True, item

    def load_result_nb(self):
        ok, item = self._out.try_pop()
        if not ok or item is EOS:
            return False, None
        return True, item

    def wait(self, timeout: Optional[float] = None) -> int:
        if self._thread is not None:
            self._thread.join(timeout)
        return -1 if self.error is not None else 0

    # -- engine loop -------------------------------------------------------------
    def _admit(self) -> bool:
        admitted = False
        while self.sched.free:
            ok, req = self._in.try_pop()
            if not ok:
                break
            if req is EOS:
                self._draining = True
                break
            slot = self.sched.selectworker()
            req.tokens = []
            req.submit_t = time.perf_counter()
            self.sched.active[slot] = req
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            self.caches, self.cur_tok, self.pos = self._insert(
                self.caches, cache1, self.cur_tok, self.pos,
                jnp.asarray(slot), tok, jnp.asarray(prompt.shape[1],
                                                    jnp.int32))
            req.tokens.append(int(tok[0, 0]))
            self.active_mask[slot] = True
            admitted = True
        return admitted

    def _loop(self) -> None:
        self._draining = False
        try:
            while True:
                self._admit()
                if not self.active_mask.any():
                    if self._draining and self._in.empty():
                        break
                    ok, _peek = (not self._in.empty()), None
                    if not ok:
                        time.sleep(1e-4)
                    continue
                nt, logits, self.caches = self._decode(
                    self.params, self.caches,
                    {"token": self.cur_tok, "pos": self.pos})
                self.cur_tok = nt
                self.pos = self.pos + jnp.asarray(
                    self.active_mask, jnp.int32)  # only active slots advance
                self.steps += 1
                toks = np.asarray(nt[:, 0])
                for slot in list(self.sched.active):
                    req = self.sched.active[slot]
                    if not self.active_mask[slot]:
                        continue
                    t = int(toks[slot])
                    req.tokens.append(t)
                    finished = (len(req.tokens) >= req.max_new_tokens or
                                (self.eos_token is not None
                                 and t == self.eos_token))
                    if finished:
                        req.done = True
                        req.finish_t = time.perf_counter()
                        self.active_mask[slot] = False
                        self.sched.release(slot)
                        self._out.push(req)
        except BaseException as e:   # noqa: BLE001
            self.error = e
            import traceback
            traceback.print_exc()
        finally:
            self._out.push(EOS)
