"""Serving engine — continuous-batching decode expressed as an FFGraph
program in the paper's accelerator mode (Sec. 9).

The engine *is* a streaming network now, lowered through the single
``FFGraph.lower()`` path:

    pipeline( AdmitNode, DecodeNode, CollectNode ).wrap_around()

  AdmitNode    the SLOT SCHEDULER emitter: picks a free decode slot for each
               incoming request (paper Sec. 8.3 — user-defined scheduling),
               prefills its cache, and launches the batch tick;
  DecodeNode   the batched SPMD decode worker (all slots advance together —
               the device farm);
  CollectNode  the per-request collector: appends tokens, delivers finished
               requests (``Deliver`` escapes the loop to ``load_result``);
  feedback     the batch tick re-entering admission (``wrap_around``), i.e.
               generated tokens looping back into the decode step.

Exactly one tick circulates, so the batched state (caches / cur_tok / pos /
active_mask) is touched by one node at a time.  The host API is the paper's
accelerator API verbatim: ``run_then_freeze()`` starts the engine,
``offload(request)`` submits, ``load_result()`` blocks for the next finished
request, ``offload(FF_EOS)`` + ``wait()`` shut down.

Adaptive mode
-------------
``InferenceEngine(adaptive=True)`` attaches a
:class:`~repro.core.runtime.Supervisor` to the compiled runner for the
engine's lifetime (started by ``run_then_freeze``, stopped by ``wait``).
The engine's own nodes are stateful (slot scheduler, batched caches), so
they are never re-placed — here the supervisor is the *observer* half of
the adaptive runtime: it samples every stage's service-time EMA and lane
depths mid-serve through the uniform ``StageHandle`` surface (safe: stats
snapshot under their locks), exposes them via ``engine.stats()``, and feeds
``perf_model.observe`` so measured decode/admit service times refine the
calibration the NEXT ``compile()`` places with.  Any adaptive farm stage a
future graph adds (e.g. a tokenizer farm in front of admission) would be
resized/migrated live by the same supervisor with no engine change.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Deliver, pipeline
from ..core.node import EOS, GO_ON, FFNode, _Sentinel
from ..models.lm import LM
from ..runtime.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    id: int = -1
    # filled by the engine:
    tokens: Optional[List[int]] = None
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0


class SlotScheduler:
    """The emitter's load-balancer: free-slot tracking (selectworker)."""

    def __init__(self, n_slots: int):
        self.free = list(range(n_slots))
        self.active: Dict[int, Request] = {}

    def selectworker(self) -> Optional[int]:
        return self.free.pop() if self.free else None

    def release(self, slot: int) -> None:
        self.active.pop(slot, None)
        self.free.append(slot)


_TICK = _Sentinel("TICK")     # the circulating batch step
_DRAIN = _Sentinel("DRAIN")   # FF_EOS translated so admission can drain first


class _BatchState:
    """The batched decode state: KV caches for B slots + bookkeeping.
    Owned by whichever node currently holds the tick."""

    def __init__(self, cfg, B: int, cache_len: int):
        from ..configs.base import cache_specs
        self.caches = jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype),
                                   cache_specs(cfg, B, cache_len, None))
        self.cur_tok = jnp.zeros((B, 1), jnp.int32)
        self.pos = jnp.zeros((B,), jnp.int32)
        self.active_mask = np.zeros((B,), bool)
        self.last_toks: Optional[np.ndarray] = None


class AdmitNode(FFNode):
    """Slot-scheduler emitter: admits requests into free slots (prefill +
    cache insert) and emits the tick while any slot is live.  Terminates the
    whole loop (returns EOS) once draining and idle."""

    def __init__(self, state: _BatchState, sched: SlotScheduler, params,
                 prefill, insert):
        super().__init__()
        self.state = state
        self.sched = sched
        self.params = params
        self._prefill = prefill
        self._insert = insert
        self.pending: Deque[Request] = collections.deque()
        self.draining = False
        self.holding = True          # the tick starts in the emitter's hand

    def _admit_pending(self) -> None:
        st = self.state
        while self.pending and self.sched.free:
            req = self.pending.popleft()
            slot = self.sched.selectworker()
            req.tokens = []
            req.submit_t = time.perf_counter()
            self.sched.active[slot] = req
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill(self.params, {"tokens": prompt})
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            st.caches, st.cur_tok, st.pos = self._insert(
                st.caches, cache1, st.cur_tok, st.pos, jnp.asarray(slot),
                tok, jnp.asarray(prompt.shape[1], jnp.int32))
            req.tokens.append(int(tok[0, 0]))
            st.active_mask[slot] = True

    def _maybe_go(self):
        if not self.holding:
            return GO_ON                      # tick is downstream; queue up
        self._admit_pending()
        if self.state.active_mask.any():
            self.holding = False
            return _TICK
        if self.draining and not self.pending:
            return EOS                        # unwinds decode + collect too
        return GO_ON                          # idle: hold the tick, wait

    def svc(self, item):
        if item is _DRAIN:
            self.draining = True
        elif item is _TICK:
            self.holding = True               # back from the feedback edge
        else:
            self.pending.append(item)
        return self._maybe_go()


class DecodeNode(FFNode):
    """The batched decode worker: one SPMD step advances every active slot."""

    def __init__(self, state: _BatchState, params, decode):
        super().__init__()
        self.state = state
        self.params = params
        self._decode = decode
        self.steps = 0

    def svc(self, _tick):
        st = self.state
        nt, logits, st.caches = self._decode(
            self.params, st.caches, {"token": st.cur_tok, "pos": st.pos})
        st.cur_tok = nt
        st.pos = st.pos + jnp.asarray(st.active_mask, jnp.int32)
        self.steps += 1
        st.last_toks = np.asarray(nt[:, 0])
        return _TICK


class CollectNode(FFNode):
    """Per-request collector: routes each slot's token to its request,
    delivers finished requests out of the loop, feeds the tick back."""

    def __init__(self, state: _BatchState, sched: SlotScheduler,
                 eos_token: Optional[int]):
        super().__init__()
        self.state = state
        self.sched = sched
        self.eos_token = eos_token

    def svc(self, _tick):
        st = self.state
        for slot in list(self.sched.active):
            req = self.sched.active[slot]
            if not st.active_mask[slot]:
                continue
            t = int(st.last_toks[slot])
            req.tokens.append(t)
            finished = (len(req.tokens) >= req.max_new_tokens or
                        (self.eos_token is not None and t == self.eos_token))
            if finished:
                req.done = True
                req.finish_t = time.perf_counter()
                st.active_mask[slot] = False
                self.sched.release(slot)
                self.ff_send_out(Deliver(req))
        return _TICK                          # wrap_around -> AdmitNode


class InferenceEngine:
    """Continuous-batching engine: an FFGraph program behind the paper's
    accelerator surface (the compat adapter is ``HostRunner``)."""

    def __init__(self, cfg, plan, params, *, max_batch: int = 4,
                 cache_len: int = 256, eos_token: Optional[int] = None,
                 adaptive: bool = False):
        self.cfg = cfg
        self.plan = plan
        self.params = params
        self.B = max_batch
        self.cache_len = cache_len
        self.eos_token = eos_token
        self.model = LM(cfg)

        prefill = jax.jit(make_prefill_step(cfg, plan, cache_len))
        decode = jax.jit(make_decode_step(cfg, plan, cache_len))
        insert = jax.jit(self._insert_impl)

        self.state = _BatchState(cfg, self.B, cache_len)
        self.sched = SlotScheduler(self.B)
        self._admit = AdmitNode(self.state, self.sched, params, prefill,
                                insert)
        self._decode_node = DecodeNode(self.state, params, decode)
        self._collect = CollectNode(self.state, self.sched, eos_token)

        self.graph = pipeline(self._admit, self._decode_node,
                              self._collect).wrap_around()
        # admission back-pressure: the bounded-lane property of the old
        # 256-slot input queue — offload() blocks once this many requests
        # are waiting for a slot, instead of growing host memory unboundedly
        self.max_pending = 256
        # staged compiler: every node here is stateful (slot scheduler,
        # batched caches, per-request bookkeeping) so place() pins the whole
        # feedback loop to host threads — the SPMD decode step inside
        # DecodeNode is already the device side of the program
        self._runner = self.graph.compile(capacity=self.max_pending,
                                          results_capacity=1024,
                                          adaptive=adaptive)
        self.placements = getattr(self._runner, "placements", [])
        # adaptive mode (module docstring): a Supervisor samples the running
        # engine's stages and feeds the cost model; started/stopped with the
        # engine's own lifecycle below
        self.supervisor = None
        if adaptive:
            from ..core.runtime import Supervisor
            self.supervisor = Supervisor(self._runner)

    @property
    def steps(self) -> int:
        return self._decode_node.steps

    @property
    def error(self) -> Optional[BaseException]:
        return self._runner.error()

    def stats(self) -> dict:
        """Runner stats: per-node service-time EMA, items, lane depths."""
        s = self._runner.stats()
        if self.supervisor is not None:
            s["supervisor"] = self.supervisor.stats()
        return s

    def replacement_events(self):
        """Re-placement events (for the launcher's placement report)."""
        if self.supervisor is not None:
            return list(self.supervisor.events)
        return self._runner.replacement_events()

    # -- caches -----------------------------------------------------------------
    def _insert_impl(self, caches, new_cache, cur_tok, pos, slot, tok, p):
        """Write a single prefilled (B=1) cache into slot ``slot``."""
        def put(c, n):
            # c: (L, B, ...) or nested; n: (L, 1, ...)
            return jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
        caches = jax.tree.map(put, caches, new_cache)
        cur_tok = jax.lax.dynamic_update_slice(cur_tok, tok, (slot, 0))
        pos = pos.at[slot].set(p)
        return caches, cur_tok, pos

    # -- paper accelerator API -----------------------------------------------------
    def run_then_freeze(self) -> int:
        rc = self._runner.run_then_freeze()
        if self.supervisor is not None:
            self.supervisor.start()
        return rc

    def offload(self, req) -> None:
        """Submit a request (single producer, as in the paper's accelerator
        mode).  Blocks once ``max_pending`` requests are waiting for a slot —
        counting both the admission list and the not-yet-admitted input
        queue — so host memory stays bounded under overload."""
        delay = 1e-5
        while (req is not EOS and self.error is None
               and (len(self._admit.pending)
                    + self._runner.pending_inputs()) >= self.max_pending):
            time.sleep(delay)
            delay = min(delay * 2, 1e-2)    # park, don't spin, while throttled
        self._runner.offload(_DRAIN if req is EOS else req)

    def load_result(self, timeout: Optional[float] = None):
        return self._runner.load_result(timeout)

    def load_result_nb(self):
        return self._runner.load_result_nb()

    def wait(self, timeout: Optional[float] = None) -> int:
        rc = self._runner.wait(timeout)
        if self.supervisor is not None and self.supervisor._thread is not None:
            self.supervisor.stop()
        return rc
