from .engine import InferenceEngine, Overloaded, Request, RequestHandle

__all__ = ["InferenceEngine", "Overloaded", "Request", "RequestHandle"]
